"""Low-overhead structured telemetry: counters/gauges/histograms + spans.

Design constraints (see the package docstring for the naming scheme):

* one monotonic clock (`time.perf_counter`) for every span, stored
  relative to the instance's ``t0`` so exporters never see wall-clock;
* parent/child links from a per-thread open-span stack, so nested
  ``with tel.span(...)`` blocks reconstruct as a tree;
* a bounded, thread-safe ring buffer of closed spans (oldest dropped,
  drop count kept) so long serving runs cannot grow without bound;
* near-zero cost when disabled: ``span()`` returns a shared no-op
  singleton and ``count``/``gauge``/``observe`` return after a single
  attribute check — no telemetry objects are allocated.
  ``spans_opened`` counts every span/event ever opened on the instance
  (including ones the ring later dropped), which is what the overhead
  contract test asserts stays flat across a disabled run.

The process-global plane is ``TELEMETRY`` (disabled by default).
Instrumented layers accept ``telemetry=None`` meaning "the global
plane", so ``TELEMETRY.enable()`` before construction lights up the
whole stack and the default costs nothing.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Any, Dict, List, Optional


class Span:
    """A closed ``[t_start, t_end)`` interval on the telemetry clock.

    Times are seconds relative to the owning :class:`Telemetry`'s
    ``t0``.  ``parent_id`` is the ``span_id`` of the span that was open
    on the same thread when this one started (None for roots and
    retrospective spans).
    """

    __slots__ = ("name", "t_start", "t_end", "span_id", "parent_id",
                 "thread", "attrs")

    def __init__(self, name: str, t_start: float, t_end: float,
                 span_id: int, parent_id: Optional[int], thread: int,
                 attrs: Dict[str, Any]):
        self.name = name
        self.t_start = t_start
        self.t_end = t_end
        self.span_id = span_id
        self.parent_id = parent_id
        self.thread = thread
        self.attrs = attrs

    @property
    def duration(self) -> float:
        return self.t_end - self.t_start

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"Span({self.name!r}, [{self.t_start:.6f},"
                f" {self.t_end:.6f}), id={self.span_id},"
                f" parent={self.parent_id}, attrs={self.attrs})")


class _NullSpan:
    """Shared no-op span: the disabled path allocates nothing."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def note(self, **attrs) -> None:
        pass


NULL_SPAN = _NullSpan()


class _LiveSpan:
    """Context manager for an open span on an enabled plane."""

    __slots__ = ("_tel", "name", "attrs", "span_id", "parent_id",
                 "_t_start")

    def __init__(self, tel: "Telemetry", name: str,
                 attrs: Dict[str, Any]):
        self._tel = tel
        self.name = name
        self.attrs = attrs
        self.span_id = tel._new_id()
        self.parent_id: Optional[int] = None
        self._t_start = 0.0

    def note(self, **attrs) -> None:
        """Attach attrs discovered mid-span (e.g. steps after collect)."""
        self.attrs.update(attrs)

    def __enter__(self) -> "_LiveSpan":
        stack = self._tel._stack()
        self.parent_id = stack[-1].span_id if stack else None
        stack.append(self)
        self._t_start = time.perf_counter()
        return self

    def __exit__(self, *exc) -> bool:
        t_end = time.perf_counter()
        tel = self._tel
        stack = tel._stack()
        if stack and stack[-1] is self:
            stack.pop()
        elif self in stack:  # pragma: no cover - unbalanced exit
            stack.remove(self)
        tel._close(Span(self.name, self._t_start - tel.t0,
                        t_end - tel.t0, self.span_id, self.parent_id,
                        threading.get_ident(), self.attrs))
        return False


class Telemetry:
    """Thread-safe registry of counters, gauges, histograms and spans."""

    def __init__(self, enabled: bool = False, max_spans: int = 65536):
        self._lock = threading.Lock()
        self.enabled = enabled
        self.t0 = time.perf_counter()
        self.max_spans = max_spans
        self.counters: Dict[str, float] = {}
        self.gauges: Dict[str, float] = {}
        # name -> [count, sum, min, max]
        self.histograms: Dict[str, List[float]] = {}
        self._spans: deque = deque(maxlen=max_spans)
        self._tls = threading.local()
        self._next_id = 0
        self.spans_opened = 0
        self.spans_dropped = 0

    # -- lifecycle ----------------------------------------------------
    def enable(self, max_spans: Optional[int] = None) -> "Telemetry":
        if max_spans is not None and max_spans != self.max_spans:
            self.max_spans = max_spans
            with self._lock:
                self._spans = deque(self._spans, maxlen=max_spans)
        self.enabled = True
        return self

    def disable(self) -> "Telemetry":
        self.enabled = False
        return self

    def reset(self) -> "Telemetry":
        """Clear all recorded state (keeps the enabled flag and clock)."""
        with self._lock:
            self.counters.clear()
            self.gauges.clear()
            self.histograms.clear()
            self._spans.clear()
            self.spans_dropped = 0
        return self

    def now(self) -> float:
        """Absolute monotonic time, same clock spans are stamped with."""
        return time.perf_counter()

    # -- internals ----------------------------------------------------
    def _new_id(self) -> int:
        with self._lock:
            self._next_id += 1
            self.spans_opened += 1
            return self._next_id

    def _stack(self) -> list:
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = self._tls.stack = []
        return stack

    def _close(self, span: Span) -> None:
        with self._lock:
            if len(self._spans) == self._spans.maxlen:
                self.spans_dropped += 1
            self._spans.append(span)

    # -- metrics ------------------------------------------------------
    def count(self, name: str, n: float = 1) -> None:
        if not self.enabled:
            return
        with self._lock:
            self.counters[name] = self.counters.get(name, 0) + n

    def gauge(self, name: str, value: float) -> None:
        if not self.enabled:
            return
        with self._lock:
            self.gauges[name] = value

    def observe(self, name: str, value: float) -> None:
        if not self.enabled:
            return
        with self._lock:
            h = self.histograms.get(name)
            if h is None:
                self.histograms[name] = [1, value, value, value]
            else:
                h[0] += 1
                h[1] += value
                h[2] = min(h[2], value)
                h[3] = max(h[3], value)

    # -- spans --------------------------------------------------------
    def span(self, name: str, **attrs):
        """Open a live span: ``with tel.span("round.dispatch", r=3):``."""
        if not self.enabled:
            return NULL_SPAN
        return _LiveSpan(self, name, attrs)

    def event(self, name: str, **attrs) -> None:
        """Record a zero-length span at now."""
        if not self.enabled:
            return
        t = time.perf_counter() - self.t0
        stack = self._stack()
        parent = stack[-1].span_id if stack else None
        self._close(Span(name, t, t, self._new_id(), parent,
                         threading.get_ident(), attrs))

    def record_span(self, name: str, t_start: float, t_end: float, *,
                    parent_id: Optional[int] = None,
                    **attrs) -> Optional[int]:
        """Record a retrospective span from absolute perf_counter times.

        Used for device-side windows stamped by round handles and for
        simulator replays; returns the new span_id (for explicit
        parent linking) or None when disabled.
        """
        if not self.enabled:
            return None
        sid = self._new_id()
        self._close(Span(name, t_start - self.t0, t_end - self.t0, sid,
                         parent_id, threading.get_ident(), attrs))
        return sid

    def spans(self, name: Optional[str] = None,
              prefix: Optional[str] = None) -> List[Span]:
        """Snapshot of the ring, oldest first, optionally filtered."""
        with self._lock:
            out = list(self._spans)
        if name is not None:
            out = [s for s in out if s.name == name]
        if prefix is not None:
            out = [s for s in out if s.name.startswith(prefix)]
        return out

    # -- snapshots ----------------------------------------------------
    def counter_snapshot(self) -> Dict[str, float]:
        with self._lock:
            return dict(self.counters)

    def metric_snapshot(self) -> Dict[str, Dict[str, Any]]:
        with self._lock:
            return {"counters": dict(self.counters),
                    "gauges": dict(self.gauges),
                    "histograms": {k: {"count": v[0], "sum": v[1],
                                       "min": v[2], "max": v[3]}
                                   for k, v in self.histograms.items()}}


#: Process-global plane; disabled by default so the stack costs nothing.
TELEMETRY = Telemetry(enabled=False)


def get_telemetry(tel: Optional[Telemetry] = None) -> Telemetry:
    """Resolve a layer's ``telemetry=None`` arg to the global plane."""
    return TELEMETRY if tel is None else tel


def record_timeline(tel: Telemetry, entry, *, base: float,
                    prefix: str = "timeline", **attrs) -> None:
    """Re-express a ``TenantTimeline`` entry as two spans on the plane.

    ``entry`` keeps its API (the scheduler still appends it to
    ``timeline``/``admission_timeline``); this mirrors its transfer and
    compute windows as ``<prefix>.transfer`` / ``<prefix>.compute``
    spans.  ``base`` is the absolute perf_counter origin the entry's
    relative stamps were taken against.
    """
    if not tel.enabled:
        return
    common = dict(vdev=entry.vdev, pdev=entry.pdev, slot=entry.slot,
                  **attrs)
    pid = tel.record_span(f"{prefix}.transfer",
                          base + entry.transfer_start,
                          base + entry.transfer_end, **common)
    tel.record_span(f"{prefix}.compute", base + entry.compute_start,
                    base + entry.compute_end, parent_id=pid, **common)
