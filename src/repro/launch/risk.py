"""Risk-application driver: run Aggregate Risk Analysis under a tenancy plan.

    PYTHONPATH=src python -m repro.launch.risk --reduced --tenants 2 \
        --mode sequential

Prints the YLT risk metrics and the staging/compute timeline, plus the
perf/energy-model prediction for the same deployment (paper Figs 15-22).
"""
from __future__ import annotations

import argparse
import dataclasses
import sys

import jax.numpy as jnp

from repro.configs.risk_app import CONFIG as PAPER_CFG
from repro.core import energymodel as em
from repro.core import perfmodel as pm
from repro.core.planner import plan
from repro.risk import metrics
from repro.risk.analysis import AggregateRiskAnalysis
from repro.risk.tables import generate


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--trials", type=int, default=None)
    ap.add_argument("--tenants", type=int, default=2)
    ap.add_argument("--mode", default="sequential",
                    choices=["sequential", "concurrent"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = PAPER_CFG.reduced() if args.reduced else PAPER_CFG
    repl = {"tenants_per_device": args.tenants, "transfer_mode": args.mode}
    if args.trials:
        repl["num_trials"] = args.trials
    cfg = dataclasses.replace(cfg, **repl)

    tables = generate(cfg, args.seed)
    ara = AggregateRiskAnalysis(cfg)
    rep = ara.run_tenant_chunked(tables)
    print(f"trials={cfg.num_trials} tenants/dev={args.tenants} "
          f"mode={args.mode} wall={rep.wall_s*1e3:.1f} ms")
    for k, v in metrics.summary(jnp.asarray(rep.ylt)).items():
        print(f"  {k:8s} {float(v):,.0f}")

    # model-predicted deployment for the paper-scale workload
    m = pm.PerfModelInputs(net=pm.FDR)
    best = plan(m, "time")
    beste = plan(m, "energy")
    print(f"paper-scale model: time-opt {best.n_pdev}x{best.tenants_per_pdev}"
          f" = {best.exec_time_s:.3f}s | energy-opt "
          f"{beste.n_pdev}x{beste.tenants_per_pdev} = {beste.energy_ws:.0f} Ws")
    return 0


if __name__ == "__main__":
    sys.exit(main())
