import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()
# The two lines above MUST run before any other import (jax locks the device
# count on first init).  Everything below is ordinary.
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces, without allocating any device memory:
  * compiled.memory_analysis()   -> bytes per device (proves it fits)
  * compiled.cost_analysis()     -> HLO FLOPs / bytes for the roofline
  * a collective-traffic table parsed from the compiled HLO text

XLA's HloCostAnalysis visits while-loop bodies ONCE (it cannot know trip
counts), so the scan-over-layers/microbatches/attention-blocks would be
undercounted.  We therefore also lower two *auxiliary* configs with python-
unrolled loops (num_layers = period and 2*period, microbatches=1) and linearly
extrapolate FLOPs / bytes / collective traffic in the stage count — exact for
anything linear in depth, which all these stacks are.  memory_analysis always
comes from the real (scanned, microbatched) artifact.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch internlm2-1.8b \
      --shape train_4k --mesh pod          # 16x16, 256 chips
  PYTHONPATH=src python -m repro.launch.dryrun --all   # every cell, both meshes
"""
import argparse
import dataclasses
import json
import pathlib
import re
import subprocess
import sys
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import (ARCH_IDS, SHAPES, ArchConfig, cell_is_applicable,
                           get_config, get_shape)
from repro.distributed.sharding import Sharder, param_shardings
from repro.launch.mesh import make_production_mesh
from repro.models import params as pp
from repro.models.model import build_model, input_specs
from repro.training.optimizer import make_optimizer
from repro.training.train_loop import build_train_step

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"

# matches only *defining* collective instructions:  %x = <shape> all-reduce(
COLLECTIVE_RE = re.compile(
    r"=\s*(\([^=]*?\)|[a-z0-9]+\[[0-9,]*\]\S*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
DTYPE_BYTES = {"f32": 4, "f16": 2, "bf16": 2, "f64": 8, "s32": 4, "u32": 4,
               "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s64": 8, "u64": 8,
               "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}
COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
              "collective-permute")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * DTYPE_BYTES[dtype]


def parse_collectives(hlo_text: str) -> Dict[str, Any]:
    """Per-device collective traffic from the (post-SPMD) HLO text.

    For each collective instruction, the largest shape on the line (covers
    all-gather results and all-reduce operands) is its per-device payload;
    ring all-reduce moves ~2x its payload (reduce-scatter + all-gather phases).
    """
    out = {k: {"count": 0, "bytes": 0} for k in COLL_KINDS}
    for line in hlo_text.splitlines():
        m = COLLECTIVE_RE.search(line)
        if not m:
            continue
        result_shapes, kind = m.group(1), m.group(2)
        # payload = sum of the result tuple's element sizes
        payload = sum(_shape_bytes(d, s)
                      for d, s in SHAPE_RE.findall(result_shapes))
        if payload == 0:
            continue
        mult = 2 if kind == "all-reduce" else 1
        out[kind]["count"] += 1
        out[kind]["bytes"] += payload * mult
    out["total_bytes"] = int(sum(v["bytes"] for v in out.values()
                                 if isinstance(v, dict)))
    return out


def _attach(sds_tree, shard_tree):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        sds_tree, shard_tree)


def _batch_axes(specs: Dict[str, jax.ShapeDtypeStruct]):
    return {k: ("batch",) + (None,) * (len(v.shape) - 1)
            for k, v in specs.items()}


def _lower(cfg: ArchConfig, shape, mesh, sh: Sharder):
    """Lower the cell's step function.  Returns jax.stages.Lowered."""
    bundle = build_model(cfg)
    boxed_sds = jax.eval_shape(bundle.init, jax.random.PRNGKey(0))
    p_sds, p_axes = pp.split(boxed_sds)
    p_in = _attach(p_sds, param_shardings(sh, p_axes, p_sds))

    specs = input_specs(cfg, shape)
    b_shard = jax.tree.map(lambda s, a: sh.named(a, s.shape), specs,
                           _batch_axes(specs),
                           is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    b_in = _attach(specs, b_shard)

    if shape.kind == "train":
        opt = make_optimizer(cfg)
        step_fn = build_train_step(bundle, sh, opt)
        o_sds = jax.eval_shape(lambda p: opt.init(p), p_sds)
        o_axes = opt.state_axes(p_axes, p_sds)
        o_in = _attach(o_sds, param_shardings(sh, o_axes, o_sds))
        state_in = {"params": p_in, "opt": o_in,
                    "step": jax.ShapeDtypeStruct((), jnp.int32)}
        with mesh:
            return jax.jit(step_fn).lower(state_in, b_in)
    if shape.kind == "prefill":
        def prefill(params, batch):
            return bundle.prefill_fn(params, batch, sh)
        with mesh:
            return jax.jit(prefill).lower(p_in, b_in)
    # decode
    c_sds = jax.eval_shape(
        lambda: bundle.init_caches(shape.global_batch, shape.seq_len))
    c_axes = bundle.cache_axes()
    c_in = _attach(c_sds, param_shardings(sh, c_axes, c_sds))

    def decode(params, tokens, caches, idx):
        return bundle.decode_fn(params, tokens, caches, idx, sh)
    idx_in = jax.ShapeDtypeStruct((), jnp.int32)
    with mesh:
        return jax.jit(decode).lower(p_in, b_in["tokens"], c_in, idx_in)


def _cost_of(compiled) -> Dict[str, float]:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
            "transcendentals": float(cost.get("transcendentals", 0.0))}


def _aux_metrics(cfg: ArchConfig, shape, mesh, sh: Sharder,
                 n_layers: int) -> Dict[str, Any]:
    """Unrolled lowering of a shallow variant; exact per-stage costs."""
    repl = {"num_layers": n_layers, "microbatches": 1}
    if cfg.enc_dec:
        repl["num_encoder_layers"] = max(
            1, cfg.num_encoder_layers * n_layers // cfg.num_layers)
    aux_cfg = dataclasses.replace(cfg, **repl)
    os.environ["REPRO_UNROLL"] = "1"
    try:
        lowered = _lower(aux_cfg, shape, mesh, sh)
        with mesh:
            compiled = lowered.compile()
    finally:
        os.environ["REPRO_UNROLL"] = "0"
    out = _cost_of(compiled)
    out["collectives"] = parse_collectives(compiled.as_text())
    return out


def _extrapolate(v1: Dict, v2: Dict, n: float) -> Dict[str, Any]:
    """Linear in stage count: v(n) = v1 + (v2 - v1) * (n - 1)."""
    lin = lambda a, b: a + (b - a) * (n - 1)
    out = {k: lin(v1[k], v2[k]) for k in ("flops", "bytes_accessed",
                                          "transcendentals")}
    colls = {}
    for kind in COLL_KINDS:
        colls[kind] = {
            "count": int(round(lin(v1["collectives"][kind]["count"],
                                   v2["collectives"][kind]["count"]))),
            "bytes": int(round(lin(v1["collectives"][kind]["bytes"],
                                   v2["collectives"][kind]["bytes"]))),
        }
    colls["total_bytes"] = int(sum(c["bytes"] for c in colls.values()
                                   if isinstance(c, dict)))
    out["collectives"] = colls
    return out


def lower_risk_cell(shape_name: str, multi_pod: bool) -> Dict[str, Any]:
    """Dry-run the paper's own workload: one tenant wave of Aggregate Risk
    Analysis sharded over every mesh axis (trials are embarrassingly
    parallel).  shape risk_1m_t<k>: 1M trials split over k tenant waves."""
    import dataclasses as _dc

    from repro.configs.risk_app import CONFIG as RISK_CFG
    from repro.risk.analysis import AggregateRiskAnalysis

    tenants = int(shape_name.rsplit("_t", 1)[1])
    rec: Dict[str, Any] = {
        "arch": "risk-analysis", "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": 512 if multi_pod else 256, "kind": "risk",
        "tenants": tenants,
    }
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = RISK_CFG

    def _metrics(events_per_trial: int, chunk: int):
        c = _dc.replace(cfg, events_per_trial=events_per_trial,
                        chunk_events=chunk)
        ara = AggregateRiskAnalysis.__new__(AggregateRiskAnalysis)
        ara.cfg = c
        step = ara.make_sharded_step(mesh, chunk=chunk)
        # one tenant wave, rounded to a chip multiple (last wave is ragged
        # on the host side; the lowered step shape is the common case)
        t_step = max(512, (cfg.num_trials // tenants // 512) * 512)
        specs = ara.input_specs(t_step)
        yet_in = jax.ShapeDtypeStruct(
            specs["yet"].shape, specs["yet"].dtype,
            sharding=jax.NamedSharding(
                mesh, jax.sharding.PartitionSpec(tuple(mesh.axis_names))))
        args = [yet_in] + [specs[k] for k in
                           ("elt", "occ_ret", "occ_lim", "agg_ret", "agg_lim")]
        with mesh:
            lowered = jax.jit(step).lower(*args)
            compiled = lowered.compile()
        return lowered, compiled

    t0 = time.time()
    _, compiled = _metrics(cfg.events_per_trial, cfg.chunk_events)
    rec["compile_s"] = round(time.time() - t0, 2)
    mem = compiled.memory_analysis()
    rec["memory"] = {
        "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
        "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
        "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
        "generated_code_bytes": 0,
    }
    rec["cost_raw"] = _cost_of(compiled)
    rec["collectives_raw"] = parse_collectives(compiled.as_text())
    # the event-chunk lax.scan body is counted once: extrapolate linearly in
    # the number of chunks via 1-chunk and 2-chunk lowerings
    ck = cfg.chunk_events
    _, c1 = _metrics(ck, ck)
    _, c2 = _metrics(2 * ck, ck)
    v1 = dict(_cost_of(c1), collectives=parse_collectives(c1.as_text()))
    v2 = dict(_cost_of(c2), collectives=parse_collectives(c2.as_text()))
    ex = _extrapolate(v1, v2, cfg.events_per_trial // ck)
    rec["cost"] = {k: ex[k] for k in ("flops", "bytes_accessed",
                                      "transcendentals")}
    rec["collectives"] = ex["collectives"]
    rec["status"] = "ok"
    return rec


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               exact_costs: bool = True) -> Dict[str, Any]:
    if arch == "risk-analysis":
        return lower_risk_cell(shape_name, multi_pod)
    cfg = get_config(arch)
    if os.environ.get("REPRO_MICROBATCHES"):
        cfg = dataclasses.replace(
            cfg, microbatches=int(os.environ["REPRO_MICROBATCHES"]))
    if os.environ.get("REPRO_REMAT"):
        cfg = dataclasses.replace(cfg, remat=os.environ["REPRO_REMAT"])
    shape = get_shape(shape_name)
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": 512 if multi_pod else 256,
        "kind": shape.kind,
    }
    ok, reason = cell_is_applicable(cfg, shape)
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = reason
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    fsdp = cfg.fsdp or os.environ.get("REPRO_FSDP") == "1"
    seq_shard = (fsdp if not os.environ.get("REPRO_SEQSHARD")
                 else os.environ["REPRO_SEQSHARD"] == "1")
    if os.environ.get("REPRO_DP_ONLY") == "1":
        # pure data parallelism: batch over every mesh axis, weights fully
        # FSDP-sharded, no tensor parallelism (small-arch optimised layout)
        from repro.distributed.sharding import DEFAULT_RULES
        rules = dict(DEFAULT_RULES)
        rules.update({"batch": ("pod", "data", "model"),
                      "fsdp": ("pod", "data", "model"),
                      "heads": None, "kv": None, "ff": None, "vocab": None,
                      "inner": None, "expert": None, "seq": None,
                      "kvseq": ("model", "data")})
        sh = Sharder(mesh, fsdp=True, seq_shard=False, rules=rules)
    else:
        sh = Sharder(mesh, fsdp=fsdp, seq_shard=seq_shard)

    t0 = time.time()
    lowered = _lower(cfg, shape, mesh, sh)
    rec["lower_s"] = round(time.time() - t0, 2)

    t0 = time.time()
    with mesh:
        compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t0, 2)

    mem = compiled.memory_analysis()
    rec["memory"] = {
        "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
        "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
        "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
        "generated_code_bytes": int(getattr(mem, "generated_code_size_in_bytes", 0)),
    }
    rec["cost_raw"] = _cost_of(compiled)
    hlo = compiled.as_text()
    rec["collectives_raw"] = parse_collectives(hlo)
    rec["hlo_lines"] = hlo.count("\n")
    del compiled, lowered, hlo

    if exact_costs:
        period = cfg.stage_period if not cfg.enc_dec else 1
        n = cfg.num_layers // period
        if n >= 2:
            t0 = time.time()
            v1 = _aux_metrics(cfg, shape, mesh, sh, period)
            v2 = _aux_metrics(cfg, shape, mesh, sh, 2 * period)
            ex = _extrapolate(v1, v2, n)
            rec["cost"] = {k: ex[k] for k in ("flops", "bytes_accessed",
                                              "transcendentals")}
            rec["collectives"] = ex["collectives"]
            rec["aux_s"] = round(time.time() - t0, 2)
        else:
            rec["cost"] = rec["cost_raw"]
            rec["collectives"] = rec["collectives_raw"]
    else:
        rec["cost"] = rec["cost_raw"]
        rec["collectives"] = rec["collectives_raw"]

    rec["status"] = "ok"
    return rec


def run_one(arch: str, shape_name: str, mesh_name: str,
            out_dir: pathlib.Path, exact: bool = True) -> Dict[str, Any]:
    rec = lower_cell(arch, shape_name, mesh_name == "multipod",
                     exact_costs=exact)
    out_dir.mkdir(parents=True, exist_ok=True)
    path = out_dir / f"{arch}__{shape_name}__{mesh_name}.json"
    path.write_text(json.dumps(rec, indent=1))
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod"])
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape x mesh) cell in subprocesses")
    ap.add_argument("--no-exact", action="store_true",
                    help="skip the unrolled aux lowerings (raw costs only)")
    ap.add_argument("--out", default=str(RESULTS_DIR))
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args(argv)
    out_dir = pathlib.Path(args.out)

    if args.all:
        cells = [(a, s.name, m) for a in ARCH_IDS for s in SHAPES
                 for m in ("pod", "multipod")]
        failures = 0
        for a, s, m in cells:
            path = out_dir / f"{a}__{s}__{m}.json"
            if args.skip_existing and path.exists():
                st = json.loads(path.read_text()).get("status")
                if st in ("ok", "skipped"):
                    print(f"[skip] {a} {s} {m}: {st}", flush=True)
                    continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", a, "--shape", s, "--mesh", m, "--out", str(out_dir)]
            if args.no_exact:
                cmd.append("--no-exact")
            print(f"[run ] {a} {s} {m}", flush=True)
            t0 = time.time()
            r = subprocess.run(cmd, capture_output=True, text=True,
                               env=dict(os.environ, PYTHONPATH="src"))
            dt = round(time.time() - t0, 1)
            if r.returncode != 0:
                failures += 1
                out_dir.mkdir(parents=True, exist_ok=True)
                path.write_text(json.dumps({
                    "arch": a, "shape": s, "mesh": m, "status": "error",
                    "error": r.stderr[-4000:]}, indent=1))
                print(f"[FAIL {dt}s] {a} {s} {m}\n" + r.stderr[-1500:], flush=True)
            else:
                print(f"[ok   {dt}s] {a} {s} {m}", flush=True)
        print(f"done; {failures} failures")
        return 1 if failures else 0

    rec = run_one(args.arch, args.shape, args.mesh, out_dir,
                  exact=not args.no_exact)
    print(json.dumps(rec, indent=1))
    return 0 if rec.get("status") in ("ok", "skipped") else 1


if __name__ == "__main__":
    sys.exit(main())
