"""Multi-tenant serving driver.

    PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b \
        --reduced --tenants 3 --requests 12

Builds a reduced model, spins up the multi-tenant scheduler and drains a
synthetic request mix, printing per-tenant utilisation (the serving analogue
of the paper's Fig 14 utilisation table) plus the realised staging/decode
overlap pairs.  ``--blocking`` selects the legacy host-blocking schedule
(engine.generate per slot) for A/B against the default dispatch/await
overlap (tenant k+1 staged under tenant k's on-device decode).
"""
from __future__ import annotations

import argparse
import sys

import jax
import numpy as np

from repro.configs import get_config
from repro.core.tenancy import TenancyConfig
from repro.models import params as pp
from repro.models.model import build_model
from repro.serving.engine import ServingEngine
from repro.serving.multitenant import MultiTenantScheduler, Request


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--tenants", type=int, default=3)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--blocking", action="store_true",
                    help="legacy host-blocking schedule (A/B baseline)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params, _ = pp.split(build_model(cfg).init(jax.random.PRNGKey(0)))
    engine = ServingEngine(cfg, params)
    sched = MultiTenantScheduler(engine, max_batch=args.max_batch,
                                 tenancy=TenancyConfig(1, args.tenants),
                                 overlapped=not args.blocking)

    rng = np.random.default_rng(0)
    for i in range(args.requests):
        tenant = f"tenant-{i % args.tenants}"
        prompt = rng.integers(1, cfg.vocab_size,
                              args.prompt_len).astype(np.int32)
        sched.submit(Request(tenant, prompt, args.new_tokens))

    responses = sched.drain()
    print(f"served {len(responses)} requests")
    for t, rep in sorted(sched.utilization_report().items()):
        print(f"  {t}: requests={rep['requests']:.0f} "
              f"tokens={rep['tokens']:.0f} busy={rep['busy_s']*1e3:.0f}ms "
              f"share={rep['busy_share']*100:.1f}%")
    lat = [r.latency_s for r in responses]
    print(f"latency p50={np.percentile(lat,50)*1e3:.0f}ms "
          f"p99={np.percentile(lat,99)*1e3:.0f}ms")
    from repro.core.pipeline import timeline_overlaps
    ov = timeline_overlaps(sched.timeline)
    mode = "blocking" if args.blocking else "overlapped"
    print(f"schedule={mode} overlap_pairs={sum(ov)}/{len(ov)} "
          f"(staging of slot k+1 inside slot k's decode window)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
