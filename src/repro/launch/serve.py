"""Multi-tenant serving driver.

    PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b \
        --reduced --tenants 3 --requests 12 --mode continuous

Builds a reduced model, spins up the multi-tenant scheduler and drains a
synthetic request mix, printing per-tenant utilisation (the serving analogue
of the paper's Fig 14 utilisation table) plus the realised staging/decode
overlap pairs.  ``--mode`` selects the schedule:

* ``continuous`` — continuous batching over a persistent slot table with a
  paged KV-cache: requests are admitted into an in-flight decode (same
  prompt-bucket admissions batched into one prefill call; with
  ``--prefix-sharing`` common prompt prefixes map onto existing pages with
  copy-on-write) and retired rows are evicted, so the device never drains
  between tenant batches (also prints micro-round occupancy and
  page-sharing stats).  ``--kernel-backend pallas`` swaps the decode
  round's dense KV gather for the fused page-streaming Pallas kernels
  (token-exact; interpret mode on CPU, where it demonstrates structure,
  not speed).  Overload knobs: ``--priority K`` marks every K-th request
  tier 0, ``--swap`` (default on) lets blocked tier-0 arrivals preempt
  tier-1 rows via host-tier KV swap (token-exact restore), and
  ``--max-backlog N`` sheds the lowest-priority queued work past N with
  an explicit REJECTED outcome;
* ``overlapped`` (default) — tenant-slot batching with up to
  ``--stage-depth`` batches staged under the running decode;
* ``blocking`` — the legacy host-blocking schedule (A/B baseline).

Every arch in ``configs/`` serves under every mode (PR 9): the continuous
slot table decomposes per-request state into registered kinds — paged
attention KV, write-once cross-attention pages (encoder-decoder archs) and
checkpointable SSM slot state (SSM/hybrid archs) — and all of them swap,
so preemption works for every family.  ``--list-archs`` prints the
capability table (state kinds, preemptable, prefix sharing, exactness
class) per arch without building a model.  Encoder-decoder archs prefill
from synthetic deterministic frames, vision archs from synthetic patch
embeddings — distinct per request, so their chain keys only share when
content (prompt *and* extras) is byte-identical.  Sliding-window archs
prefix-share through window-phase chain keys: the ring layout is part of
block identity, and a block is shareable only when the *whole* prompt
content feeding its window is identical.


Crash safety (``--mode continuous`` only — the other schedules do not
journal round commits or retirements, so pairing them with
``--journal-dir`` is rejected): ``--journal-dir DIR`` arms the durable
write-ahead request journal (``DIR/journal.jsonl``, fsync'd per record —
every submission is on disk *before* it is queued) and engine checkpoints
(``DIR/checkpoints/engine_<N>/``); ``--checkpoint-every K`` snapshots the
whole serving state — every live slot's per-kind host record, the host
swap tier, queue/priority state and the prefix-trie keys — every K
committed decode rounds (engine quiesced; one pipeline bubble per
checkpoint).  After a crash (SIGKILL included), re-running with the same
``--journal-dir`` plus ``--recover`` rebuilds a fresh engine from the
latest checkpoint, re-queues journalled-but-never-checkpointed requests,
and *replays* the rounds committed after the checkpoint.  The exactness
contract matches ``--list-archs``: non-MoE archs recover bitwise
token-exact (seeded sampling folds the per-slot key by emitted-token
index, so replayed rounds regenerate identical tokens); MoE archs recover
completion-exact per their ``supported_modes`` exactness class.

Observability: ``--trace-out trace.json`` enables the telemetry plane and
writes a Chrome-trace/Perfetto JSON of every span the run recorded
(scheduler steps > round dispatch > kernel windows, KV pool activity, swap
lanes); ``--metrics-out metrics.prom`` writes the Prometheus text
exposition of the counters/gauges; ``--stats-every N`` prints a compact
``obs: k=v`` line every N scheduling steps (including the heartbeat
suspect gauge).  Any of the three lights up the global plane before the
stack is built; without them telemetry stays disabled and costs nothing.
"""
from __future__ import annotations

import argparse
import sys

import jax
import numpy as np

from repro.configs import get_config
from repro.core.tenancy import TenancyConfig
from repro.models import params as pp
from repro.models.model import build_model
from repro.serving.engine import ServingEngine
from repro.serving.multitenant import MODES, MultiTenantScheduler, Request


def list_archs() -> int:
    """Print the per-arch serving capability table (no model is built:
    the probe reads the arch config alone)."""
    from repro.configs import ARCH_IDS
    from repro.serving.continuous import ContinuousBatchingEngine
    hdr = (f"{'arch':<28} {'modes':<32} {'state kinds':<16} "
           f"{'preempt':<8} {'share':<14} {'exactness'}")
    print(hdr)
    print("-" * len(hdr))
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        modes = ContinuousBatchingEngine.supported_modes(cfg)
        cont = modes["continuous"]
        share = ("window-phase" if cont["window_phase_keys"]
                 else ("yes" if cont["prefix_sharing"] else "no"))
        print(f"{arch:<28} "
              f"{'/'.join(m for m in MODES if modes[m]['supported']):<32} "
              f"{'+'.join(cont['state_kinds']):<16} "
              f"{'yes' if cont['preemptable'] else 'no':<8} "
              f"{share:<14} {cont['exactness']}")
    return 0


def synth_extra_inputs(cfg, rng) -> dict:
    """Deterministic synthetic non-token prefill inputs for one request:
    encoder frames for enc-dec archs, patch embeddings for vision archs
    (distinct per call — distinct extras never share pages)."""
    extra = {}
    if cfg.enc_dec:
        extra["frames"] = rng.normal(
            size=(cfg.encoder_seq_len, cfg.d_model)).astype(np.float32)
    if cfg.num_patches:
        extra["patch_embeds"] = rng.normal(
            size=(cfg.num_patches, 1024)).astype(np.float32)
    return extra


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--list-archs", action="store_true",
                    help="print the per-arch serving capability table "
                         "(modes, state kinds, preemptable, prefix "
                         "sharing, exactness class) and exit")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--tenants", type=int, default=3)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--mode", choices=MODES, default=None,
                    help="serving schedule (default: overlapped)")
    ap.add_argument("--blocking", action="store_true",
                    help="legacy alias for --mode blocking")
    ap.add_argument("--stage-depth", type=int, default=1,
                    help="overlapped mode: batches staged ahead of the "
                         "one being awaited")
    ap.add_argument("--capacity", type=int, default=4,
                    help="continuous mode: slot-table rows")
    ap.add_argument("--page-size", type=int, default=16,
                    help="continuous mode: KV-cache page size (tokens)")
    ap.add_argument("--inner-steps", type=int, default=4,
                    help="continuous mode: decode steps per micro-round")
    ap.add_argument("--prefix-sharing", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="continuous mode: refcounted prefix sharing + "
                         "copy-on-write over the paged pool")
    ap.add_argument("--batch-admission",
                    action=argparse.BooleanOptionalAction, default=True,
                    help="continuous mode: batch same-bucket admissions "
                         "into one prefill call")
    ap.add_argument("--kernel-backend", choices=("jnp", "pallas"),
                    default="jnp",
                    help="paged-attention backend: 'jnp' gathers each "
                         "row's dense logical window per decode step (A/B "
                         "baseline), 'pallas' streams pages in place "
                         "through the fused kernels (interpret mode on "
                         "CPU)")
    ap.add_argument("--preserve-pristine", choices=("never", "reuse",
                                                    "always"),
                    default="reuse",
                    help="pristine-preserve policy for shared chains: "
                         "'reuse' copies a written pristine page only "
                         "after its chain recorded a sharing hit, "
                         "'always' is the PR-4 one-copy-per-admission "
                         "behaviour, 'never' disables preservation")
    ap.add_argument("--shared-prefix-len", type=int, default=0,
                    help="prepend a common system-prompt prefix of this "
                         "many tokens to every request (demo workload for "
                         "--prefix-sharing)")
    ap.add_argument("--priority", type=int, default=0, metavar="K",
                    help="continuous mode: mark every K-th request as "
                         "tier 0 (highest priority; admitted first, shed "
                         "last, preempts tier-1 rows under slot/page "
                         "pressure when --swap is on).  0 = single-tier "
                         "traffic (default)")
    ap.add_argument("--swap", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="continuous mode: preemption via KV tiering — a "
                         "blocked higher-priority arrival swaps a lower-"
                         "priority victim's pages out to the host store "
                         "and restores them token-exactly when capacity "
                         "frees (--no-swap = admission waits instead).  "
                         "Every state kind swaps: attention and cross-"
                         "attention pages as blocks, SSM slot state as "
                         "fixed-width checkpoint records — SSM/hybrid and "
                         "encoder-decoder rows are ordinary victims")
    ap.add_argument("--max-backlog", type=int, default=None, metavar="N",
                    help="continuous mode: SLO backlog bound — when more "
                         "than N requests are queued, the lowest-priority "
                         "(then latest-deadline) queued work is shed with "
                         "an explicit REJECTED outcome instead of growing "
                         "the queue (default: unbounded)")
    ap.add_argument("--mesh", default=None, metavar="AxB",
                    help="device mesh spec, e.g. '1x8': shard the paged KV "
                         "pool and the fused decode along KV heads across "
                         "the 'model' axis (B devices); page tables, "
                         "free-list, prefix trie and refcounts stay "
                         "host-global, so admission/prefix-sharing/CoW/"
                         "preemption behave identically.  '1x1' is bitwise "
                         "token-exact with the default single-device path; "
                         "wider meshes are greedy token-exact.  Requires "
                         "A*B visible devices (e.g. XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8).  "
                         "Default: no mesh (single device)")
    ap.add_argument("--journal-dir", default=None, metavar="DIR",
                    help="continuous mode: arm crash safety — write the "
                         "durable request journal to DIR/journal.jsonl "
                         "(fsync'd write-ahead of every queue mutation) "
                         "and engine checkpoints to DIR/checkpoints/ "
                         "(default: no journal)")
    ap.add_argument("--checkpoint-every", type=int, default=0, metavar="K",
                    help="continuous mode: checkpoint the full serving "
                         "state every K committed decode rounds (needs "
                         "--journal-dir; 0 = journal only, no checkpoints)")
    ap.add_argument("--crash-at-round", type=int, default=0, metavar="N",
                    help="continuous mode: SIGKILL this process at the "
                         "N-th dispatched decode round (FaultPlane crash "
                         "injection — no unwind, no flush; exit code 137)."
                         "  Pair with --journal-dir, then re-run with "
                         "--recover to demonstrate kill-and-restart "
                         "(default: 0 = never)")
    ap.add_argument("--recover", action="store_true",
                    help="recover from --journal-dir instead of submitting "
                         "synthetic requests: rebuild the engine from the "
                         "latest checkpoint, re-queue journalled-but-"
                         "unfinished work and replay rounds past the "
                         "checkpoint (token-exact for non-MoE archs), "
                         "then drain to completion")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome-trace/Perfetto JSON of the run's "
                         "telemetry spans to PATH (enables the telemetry "
                         "plane)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write Prometheus text-format metrics to PATH "
                         "(enables the telemetry plane)")
    ap.add_argument("--stats-every", type=int, default=0, metavar="N",
                    help="print a one-line telemetry summary every N "
                         "scheduling steps (enables the telemetry plane; "
                         "0 = never)")
    args = ap.parse_args(argv)
    if args.list_archs:
        return list_archs()
    mode = args.mode or ("blocking" if args.blocking else "overlapped")

    from repro.obs import TELEMETRY
    obs_on = bool(args.trace_out or args.metrics_out or args.stats_every)
    if obs_on:
        # light the global plane before the stack is built so every layer
        # (engine, scheduler, pool, swap store, staging lanes) records
        TELEMETRY.enable()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params, _ = pp.split(build_model(cfg).init(jax.random.PRNGKey(0)))
    from repro.distributed.sharding import parse_mesh, serving_sharder
    sh = serving_sharder(parse_mesh(args.mesh)) if args.mesh else None
    engine = ServingEngine(cfg, params, sh=sh,
                           kernel_backend=args.kernel_backend)
    preserve = {"never": False, "reuse": True,
                "always": "always"}[args.preserve_pristine]
    crash_kw = {}
    if args.journal_dir:
        if mode != "continuous":
            # only the continuous collect loop journals ROUND_COMMIT/
            # RETIRE; a journal written under another mode would replay
            # every completed request as pending
            ap.error("--journal-dir requires --mode continuous")
        import os
        crash_kw = dict(
            journal=os.path.join(args.journal_dir, "journal.jsonl"),
            checkpoint_dir=os.path.join(args.journal_dir, "checkpoints"),
            checkpoint_every=args.checkpoint_every)
    elif args.recover or args.checkpoint_every:
        ap.error("--recover/--checkpoint-every need --journal-dir")
    if args.crash_at_round:
        from repro.distributed.fault import FaultPlane
        crash_kw["fault_plane"] = FaultPlane(
            crash_at_round=args.crash_at_round)
    sched = MultiTenantScheduler(
        engine, max_batch=args.max_batch,
        tenancy=TenancyConfig(1, args.tenants), mode=mode,
        stage_depth=args.stage_depth,
        preemption=args.swap, max_backlog=args.max_backlog,
        continuous=dict(capacity=args.capacity, page_size=args.page_size,
                        inner_steps=args.inner_steps,
                        prefix_sharing=args.prefix_sharing,
                        batch_admission=args.batch_admission,
                        preserve_pristine=preserve,
                        max_prompt_len=max(64, 2 * args.prompt_len
                                           + args.shared_prefix_len)),
        **crash_kw)

    if args.recover:
        s = sched.recover()
        print(f"recovered from checkpoint step={s.checkpoint_step}: "
              f"live={s.restored_live} swapped={s.restored_swapped} "
              f"requeued={s.requeued} "
              f"already_complete={len(s.already_complete)} "
              f"rounds_replayed={s.rounds_replayed} "
              f"tokens preserved={s.tokens_preserved} "
              f"replayed={s.tokens_replayed}")

    rng = np.random.default_rng(0)
    shared_prefix = rng.integers(1, cfg.vocab_size,
                                 args.shared_prefix_len).astype(np.int32)
    late: list = []         # tier-0 arrivals held back to land mid-flight
    for i in range(0 if args.recover else args.requests):
        tenant = f"tenant-{i % args.tenants}"
        prompt = rng.integers(1, cfg.vocab_size,
                              args.prompt_len).astype(np.int32)
        if args.shared_prefix_len:
            prompt = np.concatenate([shared_prefix, prompt])
        tier0 = args.priority > 0 and i % args.priority == args.priority - 1
        # per-tenant queues are FIFO within a tenant, so the high tier
        # rides its own interactive lane — otherwise a tier-0 arrival
        # queued behind its tenant's earlier tier-1 work is invisible to
        # the priority-aware admission (which only compares queue heads)
        if tier0:
            tenant += "-hi"
        req = Request(tenant, prompt,
                      max(4, args.new_tokens // 4) if tier0
                      else args.new_tokens,
                      priority=0 if tier0 else 1,
                      extra_inputs=synth_extra_inputs(cfg, rng) or None)
        # tier-0 requests arrive *after* the tier-1 work has filled the
        # slot table, so the demo exercises the preemption path instead of
        # just admitting the high tier first
        if tier0 and mode == "continuous":
            late.append(req)
        else:
            sched.submit(req)
    if not sched.pending():                   # all-tier-0 traffic: no hold
        for req in late:
            sched.submit(req)
        late = []

    # manual drain loop (same semantics as sched.drain()) so the periodic
    # stats line can fire between scheduling steps
    responses = []
    steps = 0
    while sched.pending() or late:
        r = sched.step()
        if r:
            responses.extend(r)
        steps += 1
        if late:                 # tier-0 burst lands against full slots
            for req in late:
                sched.submit(req)
            late = []
        if args.stats_every and steps % args.stats_every == 0:
            from repro.obs.export import stats_line
            print(stats_line(
                TELEMETRY,
                keys=("heartbeat.beats", "kv.pages_allocated",
                      "kv.free_pages", "swap.preemptions", "swap.restores",
                      "heartbeat.suspects"),
                step=steps, pending=sched.pending()))
    sched.close()
    n_done = sum(r.outcome == "completed" for r in responses)
    print(f"served {len(responses)} requests "
          f"(completed={n_done} "
          f"rejected={sum(r.outcome == 'rejected' for r in responses)} "
          f"failed={sum(r.outcome == 'failed' for r in responses)})")
    for t, rep in sorted(sched.utilization_report().items()):
        print(f"  {t}: requests={rep['requests']:.0f} "
              f"tokens={rep['tokens']:.0f} busy={rep['busy_s']*1e3:.0f}ms "
              f"share={rep['busy_share']*100:.1f}%")
    lat = [r.latency_s for r in responses if r.outcome == "completed"]
    if lat:
        print(f"latency p50={np.percentile(lat,50)*1e3:.0f}ms "
              f"p99={np.percentile(lat,99)*1e3:.0f}ms")
    from repro.core.pipeline import timeline_overlaps
    ov = timeline_overlaps(sched.timeline)
    print(f"schedule={mode} overlap_pairs={sum(ov)}/{len(ov)} "
          f"(staging of slot k+1 inside slot k's decode window)")
    if mode == "continuous":
        eng = sched.continuous_engine
        print(f"micro-rounds={eng.rounds} x {eng.inner_steps} steps, "
              f"slot occupancy={eng.occupancy()*100:.1f}%, "
              f"pages reused={eng.kv.pages_reused}/{eng.kv.pages_allocated}, "
              f"backend={eng.backend}, mesh={args.mesh or 'none'}")
        print(f"prefix sharing={'on' if eng.prefix_sharing else 'off'}: "
              f"pages allocated={eng.kv.pages_allocated} "
              f"shared={eng.kv.pages_shared} cow_forks={eng.kv.cow_forks} "
              f"pristine_forks={eng.kv.pristine_forks}; "
              f"prefill calls={eng.prefill_calls} "
              f"skipped={eng.prefill_skips} "
              f"(batch admission={'on' if eng.batch_admission else 'off'})")
        shed = sum(int(s["shed"]) for s in sched.stats.values())
        print(f"overload: preemption={'on' if args.swap else 'off'} "
              f"preemptions={eng.preemptions} restores={eng.restores} "
              f"shed={shed} heartbeat_suspects={sched.heartbeat_suspects}")
    if args.trace_out:
        from repro.obs.export import write_chrome_trace
        write_chrome_trace(TELEMETRY, args.trace_out)
        print(f"trace: {len(TELEMETRY.spans())} spans "
              f"({TELEMETRY.spans_opened} opened, "
              f"{TELEMETRY.spans_dropped} dropped) -> {args.trace_out}")
    if args.metrics_out:
        from repro.obs.export import write_metrics
        write_metrics(TELEMETRY, args.metrics_out)
        print(f"metrics -> {args.metrics_out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
