"""Training driver.

    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
        --reduced --steps 50 --ckpt-dir /tmp/ckpt

Wires together: config -> model bundle -> sharded init -> prefetch feed ->
supervised step loop with checkpoint/restart (distributed.fault) and
straggler-aware staging.  On this CPU container use --reduced; on a real
cluster drop it and pass --mesh pod/multipod.
"""
from __future__ import annotations

import argparse
import sys
import time
from typing import Any, Optional

import jax
import numpy as np

from repro.configs import get_config
from repro.data.tokens import DataConfig, PrefetchFeed, synth_batch
from repro.distributed import checkpoint as ckpt
from repro.distributed.fault import HeartbeatMonitor, StragglerDetector
from repro.distributed.sharding import Sharder, null_sharder, param_shardings
from repro.models import params as pp
from repro.models.model import build_model
from repro.training.optimizer import make_optimizer
from repro.training.train_loop import build_train_step, init_train_state


def make_state_and_step(cfg, mesh=None, seed: int = 0):
    sh = (Sharder(mesh, fsdp=cfg.fsdp, seq_shard=cfg.fsdp)
          if mesh is not None else null_sharder())
    bundle = build_model(cfg)
    opt = make_optimizer(cfg)
    boxed = bundle.init(jax.random.PRNGKey(seed))
    params, axes = pp.split(boxed)
    if mesh is not None:
        shards = param_shardings(sh, axes, jax.eval_shape(lambda: params))
        params = jax.tree.map(
            lambda v, s: jax.device_put(v, s) if s is not None else v,
            params, shards)
    state = init_train_state(bundle, opt, params)
    step_fn = jax.jit(build_train_step(bundle, sh, opt), donate_argnums=(0,))
    return bundle, state, step_fn, sh


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--save-every", type=int, default=25)
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    bundle, state, step_fn, sh = make_state_and_step(cfg)
    n_params = pp.count_params(state["params"])
    print(f"arch={cfg.name} params={n_params:,}")

    dc = DataConfig(args.batch, args.seq, cfg.vocab_size)
    feed = PrefetchFeed(dc, cfg)
    monitor = HeartbeatMonitor(timeout_s=600)
    detector = StragglerDetector()

    losses = []
    t_start = time.perf_counter()
    for i in range(args.steps):
        t0 = time.perf_counter()
        batch = next(feed)
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        monitor.beat()
        detector.update({0: time.perf_counter() - t0})
        if args.ckpt_dir and (i + 1) % args.save_every == 0:
            ckpt.save(args.ckpt_dir, i + 1, state)
        if (i + 1) % args.log_every == 0:
            dt = time.perf_counter() - t0
            print(f"step {i+1:4d} loss {loss:.4f} "
                  f"aux {float(metrics['aux']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} {dt*1e3:.0f} ms")
    feed.close()
    wall = time.perf_counter() - t_start
    print(f"done: {args.steps} steps in {wall:.1f}s; "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    assert np.isfinite(losses).all(), "NaN/Inf loss"
    return 0


if __name__ == "__main__":
    sys.exit(main())
